"""ReferenceChain: device-resident vs host-resident temporal chains.

The contract under test (ISSUE 4 acceptance): for the same series, a
device-resident chain must produce blobs **byte-identical** to the host
chain, its state must stay **bit-exact** with the decompressor's replay
at every step (anchor -> delta -> delta boundary included), `reset()`
must re-anchor cleanly, and reconstruction must preserve the source
dtype (float32 vs float64) end to end.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import (NumarckParams, TemporalCompressor,
                        TemporalDecompressor, compress_series,
                        decompress_step,
                        mean_error_rate, reconstruction_dtype)
from repro.core.chain import (CHAIN_AUTO, CHAIN_DEVICE, CHAIN_HOST,
                              DeviceReferenceChain, HostReferenceChain,
                              make_reference_chain, resolve_residency)
from repro.core.pipeline import reconstruct_from_indices
from repro.kernels import dequant

PARAMS = NumarckParams(error_bound=1e-3, block_bytes=1024, max_bins=2048,
                       b_max=10)


def _series(n, steps, seed, dtype=np.float32):
    """Temporal series with invalid ratios (zeros) and outlier exceptions
    sprinkled on every step, so the exception path is always exercised."""
    rng = np.random.default_rng(seed)
    base = rng.normal(1.0, 0.4, n).astype(dtype)
    base[::97] = 0.0
    out = [base]
    for t in range(steps - 1):
        nxt = (out[-1] * (1 + 0.01 * rng.standard_normal(n))).astype(dtype)
        nxt[(t * 13) % max(n // 8, 1):: 211] *= 30.0
        out.append(nxt)
    return out


def _assert_steps_equal(a, b, label=""):
    assert a.b_bits == b.b_bits, label
    assert a.block_elems == b.block_elems, label
    assert a.codec == b.codec, label
    assert a.index_blocks == b.index_blocks, f"{label}: blobs differ"
    assert np.array_equal(a.centers, b.centers), label
    if a.incomp_values is None:
        assert b.incomp_values is None, label
    else:
        assert np.array_equal(a.incomp_values, b.incomp_values), label
        assert np.array_equal(a.incomp_block_offsets,
                              b.incomp_block_offsets), label


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=600, max_value=4000))
def test_device_chain_byte_identical_to_host_chain(seed, n):
    """Property: over a >=8-step series with exceptions, host- and
    device-resident chains emit byte-identical blobs and their states
    stay bit-exact with the blob replay at every step."""
    series = _series(n, 8, seed)
    host = TemporalCompressor(PARAMS, chain=CHAIN_HOST)
    dev = TemporalCompressor(PARAMS, chain=CHAIN_DEVICE)
    replay = TemporalDecompressor()
    assert host.reference_state() is None
    for t, arr in enumerate(series):
        sh = host.add(arr)
        sd = dev.add(arr)
        _assert_steps_equal(sh, sd, f"step {t}")
        # anchor (t=0), first delta (t=1) and later deltas all bit-exact
        r = replay.add(sh)
        np.testing.assert_array_equal(r, host.reference_state(),
                                      err_msg=f"host chain, step {t}")
        np.testing.assert_array_equal(r, dev.reference_state(),
                                      err_msg=f"device chain, step {t}")
        assert r.dtype == np.float32


def test_reset_reanchors_both_residencies():
    series = _series(1500, 4, 7)
    host = TemporalCompressor(PARAMS, chain=CHAIN_HOST)
    dev = TemporalCompressor(PARAMS, chain=CHAIN_DEVICE)
    for arr in series:
        _ = host.add(arr), dev.add(arr)
    host.reset()
    dev.reset()
    replay = TemporalDecompressor()
    for t, arr in enumerate(_series(1500, 4, 8)):
        sh, sd = host.add(arr), dev.add(arr)
        if t == 0:
            assert sh.is_anchor and sd.is_anchor
        _assert_steps_equal(sh, sd, f"post-reset step {t}")
        np.testing.assert_array_equal(replay.add(sh),
                                      dev.reference_state())


def test_overlap_modes_byte_identical_across_residencies():
    """overlap x residency: all four mode combinations emit the same
    bytes."""
    series = _series(2000, 6, 21)
    ref = compress_series(series, PARAMS, chain=CHAIN_HOST)
    for overlap in (False, True):
        for chain in (CHAIN_HOST, CHAIN_DEVICE, CHAIN_AUTO):
            got = compress_series(series, PARAMS, overlap=overlap,
                                  chain=chain)
            for t, (a, b) in enumerate(zip(ref, got)):
                _assert_steps_equal(a, b,
                                    f"overlap={overlap} chain={chain} "
                                    f"step {t}")


def test_float64_round_trip_preserves_dtype():
    """Satellite: reconstruction must preserve float64 (no silent f32
    truncation, no f64 promotion of the arithmetic for f32 data)."""
    series = _series(2500, 6, 5, dtype=np.float64)
    comp = TemporalCompressor(PARAMS)           # auto residency
    replay = TemporalDecompressor()
    for t, arr in enumerate(series):
        stp = comp.add(arr)
        assert stp.dtype == "float64"
        r = replay.add(stp)
        assert r.dtype == np.float64
        np.testing.assert_array_equal(r, comp.reference_state(),
                                      err_msg=f"step {t}")
        if t:
            assert mean_error_rate(arr, r) <= PARAMS.error_bound * 1.01
    # without x64 the auto chain must have stayed on host
    expect = (CHAIN_DEVICE if jax.config.jax_enable_x64 else CHAIN_HOST)
    assert comp._chain.residency == expect


def test_reconstruction_dtype_policy():
    assert reconstruction_dtype(np.float32) == np.float32
    assert reconstruction_dtype(np.float64) == np.float64
    assert reconstruction_dtype(np.float16) == np.float32
    assert reconstruction_dtype("float64") == np.float64


def test_reconstruct_from_indices_preserves_dtype():
    from repro.core.compress import encode_device
    series = _series(1200, 2, 3, dtype=np.float64)
    prev, curr = series
    dev = encode_device(prev, curr, PARAMS)
    rec = reconstruct_from_indices(prev, dev.enc, dev.centers, curr.dtype,
                                   curr=curr)
    assert rec.dtype == np.float64
    stp = compress_series(series, PARAMS)[1]
    np.testing.assert_array_equal(rec, decompress_step(stp, prev))


def test_resolve_residency_policy():
    assert resolve_residency(CHAIN_HOST, np.float32) == CHAIN_HOST
    assert resolve_residency(CHAIN_AUTO, np.float32) == CHAIN_DEVICE
    assert resolve_residency(CHAIN_DEVICE, np.float32) == CHAIN_DEVICE
    # float16 computes in f32 but must round per step on the host
    assert resolve_residency(CHAIN_AUTO, np.float16) == CHAIN_HOST
    if not jax.config.jax_enable_x64:
        assert resolve_residency(CHAIN_AUTO, np.float64) == CHAIN_HOST
        with pytest.raises(ValueError):
            resolve_residency(CHAIN_DEVICE, np.float64)
    with pytest.raises(ValueError):
        resolve_residency("hovercraft", np.float32)


def test_make_reference_chain_flavors():
    assert isinstance(make_reference_chain(CHAIN_HOST, np.float32),
                      HostReferenceChain)
    c = make_reference_chain(CHAIN_AUTO, np.float32)
    assert isinstance(c, DeviceReferenceChain)
    c.seed(np.ones(64, np.float32))
    assert isinstance(c.peek(), jax.Array)
    np.testing.assert_array_equal(c.to_host(), np.ones(64, np.float32))


def test_chain_fork_isolates_state():
    """fork() stages an advance without mutating the parent (the
    checkpoint manager's durability ordering relies on this)."""
    from repro.core.compress import encode_device
    prev, curr = _series(1000, 2, 11)
    for residency in (CHAIN_HOST, CHAIN_DEVICE):
        c = make_reference_chain(residency, prev.dtype)
        c.seed(prev)
        before = c.to_host()
        dev = encode_device(c.peek(), curr, PARAMS)
        f = c.fork()
        f.advance(dev, curr)
        np.testing.assert_array_equal(c.to_host(), before)
        assert not np.array_equal(f.to_host(), before)


def test_caller_may_reuse_input_buffers():
    """The documented buffer contract: callers may reuse/mutate their
    input buffer immediately after add_async returns.  The device chain
    must therefore take private copies, never zero-copy aliases of the
    caller's numpy buffer."""
    series = _series(2048, 6, 33)
    for residency in (CHAIN_HOST, CHAIN_DEVICE):
        for overlap in (False, True):
            comp = TemporalCompressor(PARAMS, overlap=overlap,
                                      chain=residency)
            replay = TemporalDecompressor()
            buf = np.empty_like(series[0])
            futs = []
            for arr in series:
                buf[...] = arr            # staging buffer, reused per step
                futs.append(comp.add_async(buf))
            comp.flush()
            for t, f in enumerate(futs):
                r = replay.add(f.result())
                err = mean_error_rate(series[t], r)
                assert err <= PARAMS.error_bound * 1.01, (
                    residency, overlap, t, err)
            np.testing.assert_array_equal(r, comp.reference_state())
            comp.close()


def test_reference_state_is_a_private_copy():
    """Mutating the array reference_state() returns must not corrupt the
    chain (the host flavor used to hand out its live state)."""
    series = _series(1200, 3, 15)
    for residency in (CHAIN_HOST, CHAIN_DEVICE):
        comp = TemporalCompressor(PARAMS, chain=residency)
        replay = TemporalDecompressor()
        replay.add(comp.add(series[0]))
        st = comp.reference_state()
        st *= 1.01                       # caller scribbles on the copy
        for arr in series[1:]:
            r = replay.add(comp.add(arr))
            np.testing.assert_array_equal(r, comp.reference_state(),
                                          err_msg=residency)


def test_checkpoint_device_chain_tolerates_mixed_trees(tmp_path):
    """chain="device" must degrade to host chains per tensor for dtypes
    the device cannot hold (int counters etc.), not fail the save."""
    from repro.checkpoint.manager import CheckpointManager
    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(1, 0.1, 8192).astype(np.float32),
            "opt_count": np.arange(10, dtype=np.int64),
            "half": rng.normal(0, 1, 4096).astype(np.float16)}
    mgr = CheckpointManager(str(tmp_path), PARAMS, anchor_every=2,
                            chain=CHAIN_DEVICE)
    for s in range(3):
        tree["w"] = (tree["w"] * (1 + 1e-4 * rng.standard_normal(8192))
                     ).astype(np.float32)
        mgr.save(s, tree)
    assert mgr._recon_state["w"].residency == CHAIN_DEVICE
    assert mgr._recon_state["opt_count"].residency == CHAIN_HOST
    step, restored = mgr.restore_latest()
    assert step == 2
    np.testing.assert_array_equal(restored["opt_count"],
                                  tree["opt_count"])
    assert mean_error_rate(tree["w"], restored["w"]) <= 1e-3 * 1.01


def test_patch_exceptions_matches_host_scatter():
    """Device .at[].set scatter == the host boolean-mask patch."""
    rng = np.random.default_rng(2)
    b_bits = 5
    marker = (1 << b_bits) - 1
    n = 4096
    idx = rng.integers(0, marker + 1, n).astype(np.int32)
    recon = rng.normal(0, 1, n).astype(np.float32)
    exc = rng.normal(50, 1, int((idx == marker).sum())).astype(np.float32)
    got = np.asarray(dequant.patch_exceptions(
        np.asarray(recon), np.asarray(idx), np.asarray(exc),
        b_bits=b_bits))
    want = recon.copy()
    want[idx == marker] = exc
    np.testing.assert_array_equal(got, want)
    # no exceptions: identity
    none = np.asarray(dequant.patch_exceptions(
        np.asarray(recon), np.zeros(n, np.int32),
        np.zeros(0, np.float32), b_bits=b_bits))
    np.testing.assert_array_equal(none, recon)


def test_sharded_decompressor_preserves_float64():
    """Satellite dtype-hazard fix: the sharded decompressor must not
    truncate float64 reconstructions through the f32 kernel; without x64
    it falls back to the (bit-identical) host path."""
    from jax.sharding import Mesh
    from repro.distributed.pipeline import ShardedDecompressor
    series = _series(1800, 3, 9, dtype=np.float64)
    steps = compress_series(series, PARAMS)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sd = ShardedDecompressor(mesh, "data", use_pallas=False)
    prev = series[0]
    for stp in steps[1:]:
        want = decompress_step(stp, prev)
        got = sd.decompress(stp, prev)
        assert got.dtype == np.float64
        np.testing.assert_array_equal(got, want)
        prev = want


def test_dequantize_jnp_bit_identical_to_pallas():
    rng = np.random.default_rng(4)
    b_bits = 7
    marker = (1 << b_bits) - 1
    k = 100
    n = 3000
    idx = rng.integers(0, k, n).astype(np.int32)
    idx[::37] = marker
    prev = rng.normal(1.0, 0.3, n).astype(np.float32)
    centers = (rng.normal(0, 1e-3, k)).astype(np.float32)
    a = np.asarray(dequant.dequantize(np.asarray(idx), np.asarray(prev),
                                      np.asarray(centers), b_bits=b_bits,
                                      interpret=True))
    b = np.asarray(dequant.dequantize_jnp(np.asarray(idx), np.asarray(prev),
                                          np.asarray(centers),
                                          b_bits=b_bits))
    np.testing.assert_array_equal(a, b)
