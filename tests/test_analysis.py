"""Tests for repro-lint: the AST invariant checker (PR 8).

Fixture modules under ``tests/fixtures/lint/`` seed known-good and
known-bad shapes for each pass; the CLI tests exercise the committed
baseline (the repo itself must lint clean) and the acceptance demo --
seeding a fresh violation makes ``repro-lint`` exit nonzero.
"""
import json
import os
import textwrap

import pytest

from repro.analysis import (
    LintPass,
    Violation,
    all_passes,
    get_pass,
    load_project,
    register_pass,
)
from repro.analysis import baseline as baseline_mod
from repro.analysis.cli import main as cli_main
from repro.analysis.cli import run_lint

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(TESTS_DIR)
FIXTURES = os.path.join(TESTS_DIR, "fixtures", "lint")

ALL_RULES = {
    "concurrency-discipline",
    "dtype-hazard",
    "format-closure",
    "host-sync-in-device-path",
    "jit-cache-hygiene",
    "retry-discipline",
}


def run_rule(rule, fixture):
    project = load_project([os.path.join(FIXTURES, fixture)], root=FIXTURES)
    return get_pass(rule)().run(project)


def lines_of(violations):
    return sorted(v.line for v in violations)


# --------------------------------------------------------------- registry

def test_registry_has_all_shipped_passes():
    rules = [cls.rule for cls in all_passes()]
    assert rules == sorted(ALL_RULES)


def test_get_pass_unknown_rule_raises():
    with pytest.raises(ValueError, match="unknown lint rule"):
        get_pass("no-such-rule")


def test_register_pass_rejects_duplicate_rule():
    class Imposter(LintPass):
        rule = "host-sync-in-device-path"

    with pytest.raises(ValueError, match="duplicate lint rule"):
        register_pass(Imposter)


def test_register_pass_idempotent_for_same_class():
    cls = get_pass("dtype-hazard")
    assert register_pass(cls) is cls


# ------------------------------------------------------------- host sync

def test_host_sync_flags_syncs_in_device_resident_functions():
    vs = run_rule("host-sync-in-device-path", "bad_host_sync.py")
    # np.asarray, .item(), block_until_ready, float(x[...]) in
    # encode_device; np.asarray in the _*_shard body.
    assert lines_of(vs) == [9, 10, 11, 12, 21]
    scopes = {v.scope for v in vs}
    assert scopes == {"encode_device", "_analyze_shard"}


def test_host_sync_ignores_plain_scalars_host_helpers_and_gated_syncs():
    vs = run_rule("host-sync-in-device-path", "bad_host_sync.py")
    # float(1.5) (line 13), the telemetry-gated sync (line 16) and
    # host_helper's asarray (line 25) must not be flagged.
    assert not {13, 16, 25} & set(lines_of(vs))


def test_device_resident_decorator_extends_the_registry(tmp_path):
    p = tmp_path / "custom.py"
    p.write_text(textwrap.dedent("""\
        import numpy as np
        from repro.analysis import device_resident

        @device_resident
        def my_custom_stage(x):
            return np.asarray(x)

        def undecorated(x):
            return np.asarray(x)
        """))
    project = load_project([str(p)], root=str(tmp_path))
    vs = get_pass("host-sync-in-device-path")().run(project)
    assert [v.scope for v in vs] == ["my_custom_stage"]


# ----------------------------------------------------------- suppressions

def test_suppressions_same_line_prev_line_and_def_line():
    vs = run_rule("host-sync-in-device-path", "suppressed_host_sync.py")
    assert vs == []


def test_suppression_is_rule_specific(tmp_path):
    p = tmp_path / "wrongrule.py"
    p.write_text(textwrap.dedent("""\
        import numpy as np

        def encode_device(x):
            return np.asarray(x)  # repro-lint: disable=jit-cache-hygiene
        """))
    project = load_project([str(p)], root=str(tmp_path))
    vs = get_pass("host-sync-in-device-path")().run(project)
    assert lines_of(vs) == [4]


def test_suppression_comma_list_covers_multiple_rules(tmp_path):
    p = tmp_path / "multi.py"
    p.write_text(textwrap.dedent("""\
        import numpy as np

        def encode_device(x):
            # repro-lint: disable=host-sync-in-device-path, dtype-hazard
            return np.asarray(x, np.float64)
        """))
    project = load_project([str(p)], root=str(tmp_path))
    for rule in ("host-sync-in-device-path", "dtype-hazard"):
        assert get_pass(rule)().run(project) == []


# -------------------------------------------------------------- jit cache

def test_jit_cache_flags_per_call_traces_only():
    vs = run_rule("jit-cache-hygiene", "bad_jit.py")
    # lambda jit in _encode_shard, loop-body jit, unkeyed __init__ store.
    assert lines_of(vs) == [22, 29, 49]


def test_jit_cache_sanctions_module_scope_and_keyed_stores():
    vs = run_rule("jit-cache-hygiene", "bad_jit.py")
    flagged = set(lines_of(vs))
    # decorators (9, 14), module assignment (18), keyed stores (40, 44).
    assert not {8, 9, 13, 14, 18, 40, 44} & flagged


def test_jit_cache_lambda_message_names_the_retrace():
    vs = run_rule("jit-cache-hygiene", "bad_jit.py")
    lam = [v for v in vs if v.line == 22]
    assert len(lam) == 1 and "lambda" in lam[0].message


# ------------------------------------------------------------ concurrency

def test_concurrency_flags_all_three_contracts():
    vs = run_rule("concurrency-discipline", "bad_concurrency.py")
    assert lines_of(vs) == [14, 15, 26, 39]


def test_concurrency_allows_gated_and_labelled_shapes():
    vs = run_rule("concurrency-discipline", "bad_concurrency.py")
    flagged = set(lines_of(vs))
    # list.append under lock (21), holds_gil-gated pool use (32),
    # labelled submit (40) all pass.
    assert not {21, 32, 40} & flagged


# ---------------------------------------------------------- dtype hazards

def test_dtype_flags_wide_dtypes_in_jitted_functions():
    vs = run_rule("dtype-hazard", "bad_dtype.py")
    assert lines_of(vs) == [9, 10]


def test_dtype_exempts_x64_guarded_and_host_side_uses():
    vs = run_rule("dtype-hazard", "bad_dtype.py")
    flagged = set(lines_of(vs))
    assert not {17, 22} & flagged


# --------------------------------------------------------------- baseline

def _seed_violations():
    return run_rule("host-sync-in-device-path", "bad_host_sync.py")


def test_baseline_save_load_round_trip(tmp_path):
    vs = _seed_violations()
    bl = tmp_path / "baseline.json"
    baseline_mod.save(str(bl), vs)
    loaded = baseline_mod.load(str(bl))
    assert sorted(loaded) == sorted({v.fingerprint() for v in vs})
    new, stale = baseline_mod.diff(vs, loaded)
    assert new == [] and stale == []


def test_baseline_fingerprint_ignores_line_numbers():
    v = _seed_violations()[0]
    moved = Violation(rule=v.rule, path=v.path, line=v.line + 40,
                      scope=v.scope, message=v.message)
    new, stale = baseline_mod.diff([moved], [v.fingerprint()])
    assert new == [] and stale == []


def test_baseline_diff_reports_new_and_stale():
    vs = _seed_violations()
    known = [v.fingerprint() for v in vs[:-1]]
    new, stale = baseline_mod.diff(vs, known)
    assert new == [vs[-1]] and stale == []
    new, stale = baseline_mod.diff(vs[:-1], [v.fingerprint() for v in vs])
    assert new == [] and stale == [vs[-1].fingerprint()]


def test_baseline_missing_file_is_empty():
    assert baseline_mod.load("/nonexistent/baseline.json") == []


# --------------------------------------------------------- format closure

def test_format_closure_flags_unsanctioned_renames():
    # os.replace/os.rename outside atomic_commit (the fsync-before-rename
    # helper) are flagged; the helper's own rename is sanctioned.
    vs = run_rule("format-closure", "bad_publish.py")
    assert lines_of(vs) == [18, 22]
    assert {v.scope for v in vs} == {"sloppy_publish", "sloppy_rename"}
    assert all("atomic_commit" in v.message for v in vs)


def test_format_closure_manifest_magic_is_closed():
    # The committed container: _MANIFEST_MAGIC (NCKM) has a reader branch
    # and a test fixture, so the sub-check stays silent on the repo.
    project = load_project(
        [os.path.join(REPO_ROOT, "src", "repro", "core", "container.py")],
        root=REPO_ROOT)
    vs = get_pass("format-closure")().run(project)
    assert not [v for v in vs if "_MANIFEST_MAGIC" in v.message], vs


def test_format_closure_checksum_frame_is_closed():
    # The committed container: the NCK4 checksum frame ("crc32" /
    # "block_crc32" record keys) has writer stores, reader loads and test
    # fixtures, so the sub-check stays silent on the repo.
    project = load_project(
        [os.path.join(REPO_ROOT, "src", "repro", "core", "container.py")],
        root=REPO_ROOT)
    vs = get_pass("format-closure")().run(project)
    assert not [v for v in vs if "checksum key" in v.message], vs


# -------------------------------------------------------- retry discipline

def test_retry_discipline_flags_unbounded_sleep_loops():
    vs = run_rule("retry-discipline", "bad_retry.py")
    assert {v.scope for v in vs} == {"wait_for_file", "poll_until_ready"}
    assert all("unbounded retry loop" in v.message for v in vs)


def test_retry_discipline_allows_bounded_and_exiting_loops():
    vs = run_rule("retry-discipline", "bad_retry.py")
    scopes = {v.scope for v in vs}
    assert "bounded_ok" not in scopes
    assert "exit_edge_ok" not in scopes


# ------------------------------------------------------------------- CLI

def test_cli_repo_is_clean_against_committed_baseline(capsys):
    # The acceptance gate: the shipped tree has zero NEW violations.
    rc = cli_main(["--root", REPO_ROOT])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 new violation(s)" in out


def test_cli_committed_baseline_has_no_stale_entries(capsys):
    rc = cli_main(["--root", REPO_ROOT])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 stale" in out


def test_cli_seeded_violation_exits_nonzero(tmp_path, capsys):
    # The ISSUE demo: a bare jax.jit in a _*_shard body and an asarray in
    # encode_device must turn the build red.
    p = tmp_path / "seeded.py"
    p.write_text(textwrap.dedent("""\
        import jax
        import numpy as np

        def encode_device(x):
            return np.asarray(x)

        def _quant_shard(x):
            return jax.jit(lambda y: y + 1)(x)
        """))
    rc = cli_main([str(p), "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "host-sync-in-device-path" in out
    assert "jit-cache-hygiene" in out


def test_cli_select_narrows_to_one_rule(tmp_path, capsys):
    p = tmp_path / "seeded.py"
    p.write_text("import numpy as np\n\n"
                 "def encode_device(x):\n"
                 "    return np.asarray(x)\n")
    rc = cli_main([str(p), "--root", str(tmp_path),
                   "--select", "jit-cache-hygiene"])
    assert rc == 0            # the host-sync finding is out of scope
    rc = cli_main([str(p), "--root", str(tmp_path),
                   "--select", "host-sync-in-device-path"])
    capsys.readouterr()
    assert rc == 1


def test_cli_write_baseline_then_clean_then_regress(tmp_path, capsys):
    p = tmp_path / "seeded.py"
    p.write_text("import numpy as np\n\n"
                 "def encode_device(x):\n"
                 "    return np.asarray(x)\n")
    assert cli_main([str(p), "--root", str(tmp_path),
                     "--write-baseline"]) == 0
    bl = tmp_path / baseline_mod.DEFAULT_BASELINE
    assert bl.exists()
    payload = json.loads(bl.read_text())
    assert len(payload["entries"]) == 1
    # Accepted: the same tree now lints clean.
    assert cli_main([str(p), "--root", str(tmp_path)]) == 0
    # A NEW violation alongside the baselined one still fails.
    p.write_text(p.read_text()
                 + "\ndef decompress_step_device(x):\n"
                   "    return x.item()\n")
    capsys.readouterr()
    rc = cli_main([str(p), "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "decompress_step_device" in out


def test_cli_stale_entries_warn_but_do_not_fail(tmp_path, capsys):
    p = tmp_path / "clean.py"
    p.write_text("def host_helper(x):\n    return x\n")
    bl = tmp_path / baseline_mod.DEFAULT_BASELINE
    baseline_mod.save(str(bl), [Violation(
        rule="host-sync-in-device-path", path="clean.py", line=2,
        scope="encode_device", message="host sync `np.asarray` ...")])
    rc = cli_main([str(p), "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "stale baseline entry" in out


def test_cli_no_baseline_reports_accepted_violations():
    rc = cli_main(["--root", REPO_ROOT, "--no-baseline",
                   "--select", "host-sync-in-device-path"])
    # The committed tree has accepted boundary syncs; without the
    # baseline they surface (and the exit goes red).
    assert rc == 1


def test_cli_list_rules_prints_catalogue(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule in out


def test_run_lint_sorts_by_path_line_rule():
    vs = run_lint([FIXTURES], root=FIXTURES)
    keys = [(v.path, v.line, v.rule) for v in vs]
    assert keys == sorted(keys)
    assert {v.rule for v in vs} >= ALL_RULES - {"format-closure"}
