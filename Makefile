PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-entropy bench

# Tier-1 verify (full suite).
test:
	$(PY) -m pytest -q

# Fast loop: skip the slow end-to-end markers.
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Serial vs. parallel host entropy stage across codecs / block sizes.
bench-entropy:
	$(PY) benchmarks/bench_entropy.py

bench:
	$(PY) benchmarks/run.py
