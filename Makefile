PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-dist test-multiproc test-faults lint bench-entropy \
	bench-entropy-smoke bench-chain bench bench-all bench-all-smoke \
	bench-check

# Static analysis: repro-lint (the five AST invariant passes diffed
# against repro-lint.baseline.json -- see docs/static_analysis.md) plus
# the ruff subset configured in pyproject.toml.  ruff is pinned in
# requirements-dev.txt; containers without it skip that half gracefully
# (CI always installs it, so the zero-findings gate still holds).
lint:
	$(PY) -m repro.analysis
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
	  $(PY) -m ruff check src tests benchmarks; \
	else \
	  echo "ruff not installed; skipping style gate" \
	       "(pip install -r requirements-dev.txt)"; \
	fi

# Tier-1 verify (full suite).
test:
	$(PY) -m pytest -q

# Fast loop: skip the slow end-to-end markers.
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Distributed + checkpoint suite under a 2-device host-platform mesh.
# (The sharded tests re-exec themselves with their own device count; the
# flag here covers any test that runs a mesh in-process.)
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(PY) -m pytest -q tests/test_distributed.py tests/test_checkpoint.py \
	    tests/test_sharding.py tests/test_elastic.py

# Multi-process tier: jax.distributed launch emulation, per-rank shard
# writers + NCKM manifest, crash tolerance.  The 2-process byte-identity
# tests spawn real subprocesses (repro.launch.distributed.spawn_emulated)
# and are independent of the in-process device count.
test-multiproc:
	$(PY) -m pytest -q tests/test_multiprocess.py

# Fault-tolerance tier: corruption fuzz over NCK1/2/3/4 + NCKM (every
# flip/truncation must raise a structured IntegrityError), the
# REPRO_FAULTS injection registry, the self-healing manifest commit
# (quarantine / rollback / convergence), and the injected-fleet tests.
# See docs/robustness.md.
test-faults:
	$(PY) -m pytest -q tests/test_faults.py

# Entropy stage: serial vs parallel host codecs across block sizes, plus
# the device rANS codec vs the threaded-zlib finalize at 1/16/64 MB.
# Also writes the BENCH_entropy.json artifact rows.
bench-entropy:
	$(PY) benchmarks/bench_entropy.py --json BENCH_entropy.json

# Device-codec rows only (the CI artifact): quick smoke at 1/16/64 MB.
bench-entropy-smoke:
	$(PY) benchmarks/bench_entropy.py --smoke --json BENCH_entropy.json

# Host-resident vs device-resident reference chain (single + sharded).
# Also rides along in `make bench` via bench_compression.
bench-chain:
	$(PY) benchmarks/bench_chain.py

bench:
	$(PY) benchmarks/run.py

# The committed perf trajectory: write BENCH_entropy.json,
# BENCH_chain.json, BENCH_compression.json and BENCH_scaling.json into
# the repo root in the stable diffable schema (machine/config header +
# named rows).  The scaling bench launches emulated multi-process runs.
bench-all:
	$(PY) benchmarks/run.py --bench-all --out-dir .

# Reduced in-process variant for CI: rows are a name-identical subset of
# the full bench-all rows, so bench-check gates them against the
# committed artifacts.
OUT ?= .
bench-all-smoke:
	mkdir -p $(OUT)
	$(PY) benchmarks/run.py --bench-all --smoke --out-dir $(OUT)

# Regression gate: compare fresh BENCH JSONs in $(OUT) against the
# committed ones.  TOL is the allowed fractional timing growth (local
# same-machine runs keep the 0.5 default; CI passes a generous value
# because runner hardware differs from the tracked machine).
TOL ?= 0.5
RATIO_TOL ?= 0.05
bench-check:
	@rc=0; for b in entropy chain compression scaling; do \
	  $(PY) benchmarks/check_regression.py \
	    --tracked BENCH_$$b.json --current $(OUT)/BENCH_$$b.json \
	    --tolerance $(TOL) --ratio-tolerance $(RATIO_TOL) || rc=1; \
	done; exit $$rc
