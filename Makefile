PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-dist bench-entropy bench-entropy-smoke \
	bench-chain bench

# Tier-1 verify (full suite).
test:
	$(PY) -m pytest -q

# Fast loop: skip the slow end-to-end markers.
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Distributed + checkpoint suite under a 2-device host-platform mesh.
# (The sharded tests re-exec themselves with their own device count; the
# flag here covers any test that runs a mesh in-process.)
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(PY) -m pytest -q tests/test_distributed.py tests/test_checkpoint.py \
	    tests/test_sharding.py tests/test_elastic.py

# Entropy stage: serial vs parallel host codecs across block sizes, plus
# the device rANS codec vs the threaded-zlib finalize at 1/16/64 MB.
# Also writes the BENCH_entropy.json artifact rows.
bench-entropy:
	$(PY) benchmarks/bench_entropy.py --json BENCH_entropy.json

# Device-codec rows only (the CI artifact): quick smoke at 1/16/64 MB.
bench-entropy-smoke:
	$(PY) benchmarks/bench_entropy.py --smoke --json BENCH_entropy.json

# Host-resident vs device-resident reference chain (single + sharded).
# Also rides along in `make bench` via bench_compression.
bench-chain:
	$(PY) benchmarks/bench_chain.py

bench:
	$(PY) benchmarks/run.py
