"""End-to-end driver: train a ~100M-param LM with NUMARCK-compressed
checkpoints, kill it mid-run, and restart from the compressed checkpoint.

By default runs a scaled-down model + few hundred steps so it finishes on
CPU; pass --full-width for the ~100M-parameter configuration (slower).

    PYTHONPATH=src python examples/train_restart.py
"""
import argparse
import shutil

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import NumarckParams
from repro.data.tokens import TokenPipeline
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/numarck_ckpt")
    args = ap.parse_args()

    if args.full_width:
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=12,
                          head_dim=64, d_ff=3072, vocab_size=32768,
                          dtype="float32")
    else:
        cfg = ModelConfig(name="lm-mini", family="dense", n_layers=4,
                          d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=512, vocab_size=512,
                          dtype="float32")
    model = Model(cfg)
    print(f"model {cfg.name}: {cfg.param_count():,} params")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt_dir,
                            params=NumarckParams(error_bound=1e-4),
                            anchor_every=4, keep=3)
    tcfg = TrainerConfig(opt=optim.AdamWConfig(lr=1e-3, warmup_steps=20,
                                               decay_steps=args.steps),
                         checkpoint_every=25, log_every=25)
    pipe = TokenPipeline(cfg.vocab_size, 65, 8, seed=0)

    # ---- phase 1: train to the "crash" --------------------------------
    crash_at = args.steps // 2
    tr = Trainer(model, tcfg, checkpoint_manager=mgr)
    state = tr.init_state(jax.random.PRNGKey(0))
    state, step, hist1 = tr.fit(state, iter(pipe), n_steps=crash_at)
    print(f"-- simulated crash at step {step} "
          f"(loss {hist1[0]:.3f} -> {hist1[-1]:.3f}) --")
    del tr, state

    # ---- phase 2: restart from the NUMARCK checkpoint ------------------
    mgr2 = CheckpointManager(args.ckpt_dir,
                             params=NumarckParams(error_bound=1e-4),
                             anchor_every=4, keep=3)
    tr2 = Trainer(model, tcfg, checkpoint_manager=mgr2)
    state2, start = tr2.restore_or_init(jax.random.PRNGKey(1))
    print(f"restored step {start}; resuming deterministic data stream")
    state2, step2, hist2 = tr2.fit(state2, pipe.from_step(start),
                                   start_step=start, n_steps=args.steps)
    print(f"finished at step {step2}: loss {hist2[-1]:.3f}")
    assert hist2[-1] < hist1[0], "training did not progress across restart"
    ckpts = mgr2._read_manifest()["steps"]
    print(f"checkpoints on disk: {ckpts} (anchors: "
          f"{mgr2._read_manifest()['anchors']})")


if __name__ == "__main__":
    main()
