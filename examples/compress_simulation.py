"""Full pipeline on a FLASH-stir-like simulation: multi-variable archive,
binning-strategy comparison, baselines, and partial decompression -- the
paper's Sec. V workflow end to end.

    PYTHONPATH=src python examples/compress_simulation.py
"""
import os
import tempfile

import numpy as np

from repro.baselines import isabela, zfp_like, zlib_lossless
from repro.core import (NumarckParams, TemporalArchive, compress_series,
                        mean_error_rate, decompress_series)
from repro.data.temporal import generate_series

E = 1e-3


def main():
    variables = {name: list(generate_series(name, 4, seed=13, scale=2))
                 for name in ("stir", "asr")}

    # ---- strategy comparison on stir (paper Sec. V-D) -------------------
    print("binning strategies on stir (CR of delta steps):")
    for strat in ("topk", "equal", "log", "kmeans"):
        p = NumarckParams(error_bound=E, strategy=strat,
                          b_bits=None if strat == "topk" else 8)
        steps = compress_series(variables["stir"], p)
        cr = np.mean([s.compression_ratio() for s in steps[1:]])
        me = max(mean_error_rate(o, r) for o, r in
                 zip(variables["stir"], decompress_series(steps)))
        print(f"  {strat:7s} CR={cr:5.2f}  ME={me:.2e}")

    # ---- baselines (paper Figs. 9-12) -----------------------------------
    curr = variables["stir"][-1]
    prev = variables["stir"][-2]
    from repro.core import compress_step
    st = compress_step(prev, curr, NumarckParams(error_bound=E))
    tol = float(np.mean(np.abs(curr))) * E
    print("\nvs baselines on stir (one iteration):")
    print(f"  NUMARCK  CR={st.compression_ratio():.2f}")
    print(f"  ISABELA  CR={curr.nbytes/isabela.compress(curr, E).nbytes:.2f}")
    print(f"  ZFP-like CR={curr.nbytes/zfp_like.compress(curr, tol).nbytes:.2f}")
    print(f"  ZLIB     CR={curr.nbytes/zlib_lossless.compress(curr).nbytes:.2f}")

    # ---- multi-variable archive + partial reads -------------------------
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "sim.nck")
        from repro.core.container import NCKWriter
        w = NCKWriter()
        p = NumarckParams(error_bound=E, block_bytes=1 << 14)
        for name, series in variables.items():
            for i, s in enumerate(compress_series(series, p)):
                w.add_step(f"{name}_it{i:05d}", s)
        w.write(path)
        print(f"\narchive: {os.path.getsize(path)/1e6:.2f} MB for "
              f"{sum(sum(a.nbytes for a in s) for s in variables.values())/1e6:.2f} MB raw")

        ar = TemporalArchive(path)
        n = variables["asr"][0].size
        seg = ar.read_range("asr", 3, n // 4, n // 4 + 5000)
        full = ar.read_full("asr", 3)
        np.testing.assert_array_equal(seg,
                                      full.reshape(-1)[n // 4: n // 4 + 5000])
        print("partial decompression (asr, it3, 5000 elems): exact ✓")


if __name__ == "__main__":
    main()
