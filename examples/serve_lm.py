"""Serve a small model with batched requests (prefill + streaming decode).

    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b
"""
import argparse

import jax
import numpy as np

from repro.models.model import build
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    model = build(args.arch, smoke=True)   # reduced config on CPU
    params = model.init(jax.random.PRNGKey(0))
    s_max = args.prompt_len + args.max_new
    eng = Engine(model, params, args.batch, s_max)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, model.cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, max_new=args.max_new)
    print(f"arch={model.cfg.name} (smoke config)")
    print(f"generated {out.shape} tokens")
    print(f"prefill: {eng.stats.prefill_s*1e3:.1f} ms  decode: "
          f"{eng.stats.decode_s*1e3:.1f} ms "
          f"({eng.stats.tokens_per_s:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  req{b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
