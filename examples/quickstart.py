"""Quickstart: compress a temporal dataset with parallel NUMARCK.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (NumarckParams, TemporalArchive, compress_series,
                        decompress_series, mean_error_rate)
from repro.data.temporal import generate_series


def main():
    # 6 snapshots of a turbulence-like field (FLASH-stir analogue)
    series = list(generate_series("stir", n_iterations=6, seed=0, scale=2))
    print(f"dataset: {len(series)} iterations x {series[0].shape} "
          f"{series[0].dtype} ({series[0].nbytes/1e6:.1f} MB each)")

    params = NumarckParams(error_bound=1e-3)      # E = 0.1%, auto-B, top-k
    steps = compress_series(series, params)

    total_in = sum(a.nbytes for a in series)
    total_out = sum(s.nbytes for s in steps)
    print(f"compression ratio: {total_in/total_out:.2f} "
          f"(deltas only: {np.mean([s.compression_ratio() for s in steps[1:]]):.2f})")
    for i, s in enumerate(steps):
        kind = "anchor" if s.is_anchor else f"B={s.b_bits} alpha={s.alpha:.3f}"
        print(f"  it{i}: {s.nbytes/1e6:6.2f} MB  {kind}")

    recon = decompress_series(steps)
    for i, (orig, rec) in enumerate(zip(series, recon)):
        assert mean_error_rate(orig, rec) <= params.error_bound * 1.01

    # write an archive + partial decompression
    TemporalArchive.write("/tmp/quickstart.nck", "dens", steps)
    ar = TemporalArchive("/tmp/quickstart.nck")
    window = ar.read_range("dens", 5, 1000, 1200)
    np.testing.assert_array_equal(window,
                                  recon[5].reshape(-1)[1000:1200])
    print("partial decompression of [1000:1200) at iteration 5: exact ✓")
    print(f"mean error rate (it5): "
          f"{mean_error_rate(series[5], recon[5]):.2e} <= E={params.error_bound}")


if __name__ == "__main__":
    main()
